"""Multi-device SPMD coherence tests.

These run in a SUBPROCESS with ``--xla_force_host_platform_device_count``
(the main pytest process must keep 1 device), proving the shard_map
executor and the pjit'd LM step shard correctly — the small-scale version
of the multi-pod dry-run.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_sub(code: str, devices: int = 8, timeout: int = 420) -> str:
    prog = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        + textwrap.dedent(code))
    # Pin the platform: without JAX_PLATFORMS the stripped subprocess env
    # makes jax probe for TPUs (libtpu is installed in this image) and
    # spend minutes timing out against the GCE metadata server.
    res = subprocess.run([sys.executable, "-c", prog],
                         capture_output=True, text=True, timeout=timeout,
                         env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                              "HOME": "/root",
                              "JAX_PLATFORMS": os.environ.get(
                                  "JAX_PLATFORMS", "cpu")})
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


def test_shard_map_spmv_executor():
    out = run_sub("""
        import numpy as np
        import repro.core as rc
        from repro.core.lower import (default_nnz_schedule,
                                      default_row_schedule, lower)
        from repro.core.tensor import Tensor
        from repro.data.spdata import powerlaw_matrix
        from repro.distributed.executor import to_spmd
        from repro.distributed.mesh import machine_to_mesh

        M = rc.Machine(("x", 8))
        B = powerlaw_matrix("B", 500, 400, 8, seed=0)
        c = Tensor.from_dense("c", np.random.default_rng(1)
                              .standard_normal(400).astype(np.float32))
        a = Tensor.zeros_dense("a", (500,))
        stmt = rc.parse_tin("a(i) = B(i,j) * c(j)", a=a, B=B, c=c)
        exp = B.to_dense() @ np.asarray(c.to_dense())
        mesh = machine_to_mesh(M)
        for sched in (default_row_schedule(stmt, M),
                      default_nnz_schedule(stmt, M)):
            k = lower(stmt, M, schedule=sched)
            y = to_spmd(k, mesh)()
            assert np.allclose(y, exp, atol=1e-3), k.leaf_name
            # simulation backend and SPMD backend agree exactly
            assert np.allclose(y, k.run(), atol=1e-5)
        print("SPMD_OK")
    """)
    assert "SPMD_OK" in out


def test_pjit_train_step_on_mesh():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_arch, ShapeConfig
        from repro.distributed import planner
        from repro.distributed.mesh import make_mesh
        from repro.launch import steps as steps_mod
        from repro.optim.adamw import adamw_init

        mesh = make_mesh((4, 2), ("data", "model"))
        cfg = get_arch("llama3-8b").reduced()
        shape = ShapeConfig("t", "train", seq_len=32, global_batch=8,
                            grad_accum=2)
        with mesh:
            lm = steps_mod.build_lm(cfg, mesh)
            fn, accum = steps_mod.make_train_step(lm, shape, mesh)
            params = lm.init_params(jax.random.PRNGKey(0))
            p_sh = planner.shardings_from(
                planner.params_pspecs(params, mesh), mesh)
            params = jax.device_put(params, p_sh)
            opt = adamw_init(params)
            tokens = jnp.zeros((8, 32), jnp.int32)
            new_p, new_opt, m = jax.jit(fn)(params, opt, tokens)
            assert np.isfinite(float(m["loss"]))
            # params stay sharded after the step
            leaf = jax.tree.leaves(new_p)[0]
            assert len(leaf.sharding.device_set) > 1
        print("PJIT_OK", float(m["loss"]))
    """)
    assert "PJIT_OK" in out


def test_decode_step_on_mesh_with_cache_sharding():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_arch
        from repro.distributed import planner
        from repro.distributed.mesh import make_mesh
        from repro.launch import steps as steps_mod

        mesh = make_mesh((4, 2), ("data", "model"))
        cfg = get_arch("qwen3-14b").reduced()
        with mesh:
            lm = steps_mod.build_lm(cfg, mesh)
            params = lm.init_params(jax.random.PRNGKey(0))
            cache = lm.init_cache(8, 64)
            c_sh = planner.shardings_from(
                planner.cache_pspecs(cache, mesh, 8), mesh)
            cache = jax.device_put(cache, c_sh)
            tok = jnp.zeros((8,), jnp.int32)
            logits, cache2 = jax.jit(
                lambda p, c, t: lm.decode_step(p, c, t))(params, cache, tok)
            assert not np.any(np.isnan(np.asarray(logits, np.float32)))
            assert int(np.asarray(cache2["pos"])[0]) == 1
        print("DECODE_OK")
    """)
    assert "DECODE_OK" in out


def test_hierarchical_grad_reduce_three_axes():
    out = run_sub("""
        import functools, inspect
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map
        from repro.distributed.collectives import hierarchical_grad_reduce
        from repro.distributed.mesh import make_mesh

        mesh = make_mesh((2, 4), ("pod", "data"))

        # identical local gradient on every device: the hierarchical
        # reduce-scatter(data) -> all-reduce(pod) -> all-gather(data)
        # must equal a flat psum over all 8 devices, i.e. g * 8.
        # check_vma=False: the reduce-scatter/all-gather pair restores
        # replication over 'data' but the static varying-axes check cannot
        # infer that through psum_scatter. (jax 0.4.x spells it check_rep.)
        _ck = ("check_vma" if "check_vma" in
               inspect.signature(shard_map).parameters else "check_rep")
        @functools.partial(shard_map, mesh=mesh,
                           in_specs=P(), out_specs=P(), **{_ck: False})
        def reduce_fn(g):
            return hierarchical_grad_reduce({"g": g}, intra_axis="data",
                                            inter_axis="pod")["g"]

        g = jnp.arange(8.0 * 4).reshape(8, 4)
        got = reduce_fn(g)
        assert np.allclose(np.asarray(got), np.asarray(g) * 8), got
        print("HIER_OK")
    """)
    assert "HIER_OK" in out


def test_shard_map_spmm_and_sddmm_executors():
    out = run_sub("""
        import numpy as np
        import repro.core as rc
        from repro.core.lower import (default_nnz_schedule,
                                      default_row_schedule, lower)
        from repro.core.tensor import Tensor
        from repro.data.spdata import powerlaw_matrix
        from repro.distributed.executor import to_spmd
        from repro.distributed.mesh import machine_to_mesh

        M = rc.Machine(("x", 8))
        mesh = machine_to_mesh(M)
        rng = np.random.default_rng(0)
        B = powerlaw_matrix("B", 400, 300, 8, seed=0)
        dB = B.to_dense()

        # SpMM rows
        dC = rng.standard_normal((300, 16)).astype(np.float32)
        C = Tensor.from_dense("C", dC)
        A = Tensor.zeros_dense("A", (400, 16))
        smm = rc.parse_tin("A(i,j) = B(i,k) * C(k,j)", A=A, B=B, C=C)
        k1 = lower(smm, M)
        assert np.allclose(to_spmd(k1, mesh)(), dB @ dC, atol=1e-3)

        # SDDMM nnz
        K = 8
        dCc = rng.standard_normal((400, K)).astype(np.float32)
        dDd = rng.standard_normal((K, 300)).astype(np.float32)
        Ap = Tensor("A", B.shape, B.format, B.levels,
                    np.ones_like(B.vals), B.dtype)
        sd = rc.parse_tin("A(i,j) = B(i,j) * C(i,k) * D(k,j)", A=Ap, B=B,
                          C=Tensor.from_dense("C", dCc),
                          D=Tensor.from_dense("D", dDd))
        k2 = lower(sd, M, schedule=default_nnz_schedule(sd, M))
        flat = to_spmd(k2, mesh)()
        pos, crd = B.levels[1].pos, B.levels[1].crd
        rows = np.repeat(np.arange(400), np.diff(pos))
        exp = B.vals * (dCc[rows] * dDd[:, crd].T).sum(1)
        assert np.allclose(flat, exp, atol=1e-3)
        print("SPMD2_OK")
    """)
    assert "SPMD2_OK" in out


def test_shard_map_format_general_executors():
    """Format-general lowering survives the real shard_map backend: a DCSR
    operand under the nnz SpMM executor and a COO operand under the
    row-based SDDMM executor (densified-root view) both match the oracle."""
    out = run_sub("""
        import numpy as np
        import repro.core as rc
        from repro.core import formats as F
        from repro.core.lower import (default_nnz_schedule,
                                      default_row_schedule, lower)
        from repro.core.tensor import Tensor
        from repro.distributed.executor import to_spmd
        from repro.distributed.mesh import machine_to_mesh

        M = rc.Machine(("x", 8))
        rng = np.random.default_rng(2)
        n, m, K = 96, 80, 8
        dB = ((rng.random((n, m)) < 0.1) *
              rng.standard_normal((n, m))).astype(np.float32)
        dB[5] = 0
        mesh = machine_to_mesh(M)

        B = Tensor.from_dense("B", dB, F.DCSR())
        dC = rng.standard_normal((m, 6)).astype(np.float32)
        stmt = rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                            A=Tensor.zeros_dense("A", (n, 6)), B=B,
                            C=Tensor.from_dense("C", dC))
        k = lower(stmt, M, schedule=default_nnz_schedule(stmt, M))
        assert k.fallbacks == [], k.fallbacks
        y = to_spmd(k, mesh)()
        assert np.allclose(y, dB @ dC, atol=1e-3), k.leaf_name

        Bc = Tensor.from_dense("B", dB, F.COO(2))
        dCc = rng.standard_normal((n, K)).astype(np.float32)
        dDd = rng.standard_normal((K, m)).astype(np.float32)
        A = Tensor.from_dense("A", (dB != 0) * 1.0, F.CSR())
        stmt2 = rc.parse_tin("A(i,j) = B(i,j) * C(i,k) * D(k,j)", A=A, B=Bc,
                             C=Tensor.from_dense("C", dCc),
                             D=Tensor.from_dense("D", dDd))
        k2 = lower(stmt2, M, schedule=default_row_schedule(stmt2, M))
        assert k2.fallbacks == [], k2.fallbacks
        flat = to_spmd(k2, mesh)()
        got = Tensor("A", Bc.shape, Bc.format, Bc.levels, flat, Bc.dtype)
        exp = (dB != 0) * dB * (dCc @ dDd)
        assert np.allclose(got.to_dense(), exp, atol=1e-3), k2.leaf_name
        print("FG_SPMD_OK")
    """)
    assert "FG_SPMD_OK" in out


def test_shard_map_grid_2d_executors():
    """Multi-axis distribution on a GENUINE Mesh((4, 2), ("x", "y")):
    grid rows cells psum along y only (SUMMA), grid nnz cells shard the
    flat color axis over both mesh axes and psum over both. All agree
    with the vmap simulation backend and the dense oracle."""
    out = run_sub("""
        import numpy as np
        import repro.core as rc
        from repro.core import formats as F
        from repro.core.lower import (default_grid_nnz_schedule,
                                      default_grid_schedule, lower)
        from repro.core.tensor import Tensor
        from repro.distributed.executor import to_spmd
        from repro.distributed.mesh import machine_to_mesh

        rng = np.random.default_rng(0)
        n, m, J, K = 60, 44, 9, 5
        dB = ((rng.random((n, m)) < 0.25) *
              rng.standard_normal((n, m))).astype(np.float32)
        M = rc.Machine(("x", 4), ("y", 2))
        mesh = machine_to_mesh(M)
        assert mesh.devices.shape == (4, 2)

        # SpMM, rows grid (csr + bcsr): reduction scoped to y
        for fm in (F.CSR(), F.BCSR((2, 2))):
            B = Tensor.from_dense("B", dB, fm)
            C = Tensor.from_dense(
                "C", rng.standard_normal((m, J)).astype(np.float32))
            stmt = rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                                A=Tensor.zeros_dense("A", (n, J)), B=B, C=C)
            k = lower(stmt, M, schedule=default_grid_schedule(stmt, M))
            y = to_spmd(k, mesh)()
            assert np.allclose(y, dB @ C.to_dense(), atol=1e-3), k.leaf_name
            assert np.allclose(y, k.run(), atol=1e-5), k.leaf_name

        # SpMV + SDDMM rows grid, SpMV nnz grid (flat colors over x AND y)
        B = Tensor.from_dense("B", dB, F.CSR())
        c = Tensor.from_dense(
            "c", rng.standard_normal(m).astype(np.float32))
        stmt = rc.parse_tin("a(i) = B(i,j) * c(j)",
                            a=Tensor.zeros_dense("a", (n,)), B=B, c=c)
        for sched in (default_grid_schedule(stmt, M),
                      default_grid_nnz_schedule(stmt, M)):
            k = lower(stmt, M, schedule=sched)
            y = to_spmd(k, mesh)()
            assert np.allclose(y, dB @ np.asarray(c.to_dense()),
                               atol=1e-3), k.leaf_name
            assert np.allclose(y, k.run(), atol=1e-5), k.leaf_name

        Cs = Tensor.from_dense(
            "C", rng.standard_normal((n, K)).astype(np.float32))
        D = Tensor.from_dense(
            "D", rng.standard_normal((K, m)).astype(np.float32))
        A = Tensor.from_dense("A", (dB != 0) * 1.0, F.CSR())
        stmt = rc.parse_tin("A(i,j) = B(i,j) * C(i,k) * D(k,j)",
                            A=A, B=B, C=Cs, D=D)
        k = lower(stmt, M, schedule=default_grid_schedule(stmt, M))
        y = to_spmd(k, mesh)()
        assert np.allclose(y, np.asarray(k.run().vals), atol=1e-4)
        print("GRID_SPMD_OK")
    """)
    assert "GRID_SPMD_OK" in out


def test_shard_map_grid_3d_and_replicated_executors():
    """3-D mesh executors (ISSUE 7): 2.5-D replicated SpMM/SDDMM with
    psum scoped to exactly the reduction axis the replication leaves,
    brick SpMTTKRP with psum over (y, z), and the device-count guard."""
    out = run_sub("""
        import numpy as np
        import pytest
        import repro.core as rc
        from repro.core import formats as F
        from repro.core.lower import (default_grid3_schedule,
                                      default_replicated_schedule, lower)
        from repro.core.tensor import Tensor
        from repro.distributed.executor import to_spmd
        from repro.distributed.mesh import machine_to_mesh, make_mesh

        rng = np.random.default_rng(0)
        M = rc.Machine(("x", 2), ("y", 2), ("z", 2))
        mesh = machine_to_mesh(M)
        n, m, J, K = 37, 29, 10, 5

        # 2.5-D replicated SpMM: psum over y only
        dB = ((rng.random((n, m)) < .25) *
              rng.standard_normal((n, m))).astype(np.float32)
        B = Tensor.from_dense("B", dB, F.CSR())
        C = Tensor.from_dense(
            "C", rng.standard_normal((m, J)).astype(np.float32))
        stmt = rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                            A=Tensor.zeros_dense("A", (n, J)), B=B, C=C)
        k = lower(stmt, M, schedule=default_replicated_schedule(stmt, M))
        y = to_spmd(k, mesh)()
        assert np.allclose(y, dB @ np.asarray(C.to_dense()), atol=1e-3)
        assert np.allclose(y, k.run(), atol=1e-5)

        # 2.5-D replicated SDDMM: psum over z only
        Cs = Tensor.from_dense(
            "C", rng.standard_normal((n, K)).astype(np.float32))
        D = Tensor.from_dense(
            "D", rng.standard_normal((K, m)).astype(np.float32))
        A = Tensor.from_dense("A", (dB != 0) * 1.0, F.CSR())
        stmt = rc.parse_tin("A(i,j) = B(i,j) * C(i,k) * D(k,j)",
                            A=A, B=B, C=Cs, D=D)
        k = lower(stmt, M, schedule=default_replicated_schedule(stmt, M))
        y = to_spmd(k, mesh)()
        assert np.allclose(y, np.asarray(k.run().vals), atol=1e-4)

        # brick SpMTTKRP: psum over (y, z)
        n3, m3, p3, L = 17, 13, 11, 6
        dB3 = ((rng.random((n3, m3, p3)) < .1) *
               rng.standard_normal((n3, m3, p3))).astype(np.float32)
        stmt = rc.parse_tin(
            "A(i,l) = B(i,j,k) * C(j,l) * D(k,l)",
            A=Tensor.zeros_dense("A", (n3, L)),
            B=Tensor.from_dense("B", dB3, F.COO(3)),
            C=Tensor.from_dense(
                "C", rng.standard_normal((m3, L)).astype(np.float32)),
            D=Tensor.from_dense(
                "D", rng.standard_normal((p3, L)).astype(np.float32)))
        k = lower(stmt, M, schedule=default_grid3_schedule(stmt, M))
        y = to_spmd(k, mesh)()
        assert np.allclose(y, k.run(), atol=1e-4)

        # oversized grid fails FAST with the device count in the message
        try:
            make_mesh((4, 4, 4), ("x", "y", "z"))
            raise SystemExit("mesh guard did not fire")
        except ValueError as e:
            assert "64 pieces" in str(e) and "8 visible" in str(e), str(e)
        print("GRID3_SPMD_OK")
    """)
    assert "GRID3_SPMD_OK" in out
