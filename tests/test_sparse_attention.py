"""Block-sparse attention built on the paper's format machinery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.sparse_attention import (band_plan, block_sparse_attention,
                                           mask_to_ell)


def _dense_windowed(q, k, v, window):
    S = q.shape[1]
    hd = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / hd ** 0.5
    pos = np.arange(S)
    m = (pos[None, :] <= pos[:, None]) & (pos[None, :] > pos[:, None] - window)
    w = jax.nn.softmax(jnp.where(m[None, None], scores, -1e30), -1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


@pytest.mark.parametrize("S,qb,window", [(256, 64, 128), (512, 128, 256),
                                         (300, 64, 100)])
def test_band_matches_dense_window(S, qb, window):
    key = jax.random.PRNGKey(0)
    q, k, v = (jax.random.normal(kk, (2, S, 2, 16), jnp.float32)
               for kk in jax.random.split(key, 3))
    idx = mask_to_ell(band_plan(S, qb, window))
    out = block_sparse_attention(q, k, v, idx, qb, window=window)
    ref = _dense_windowed(q, k, v, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_band_plan_nnz_scales_with_window():
    m1 = band_plan(4096, 128, 256)
    m2 = band_plan(4096, 128, 1024)
    assert m1.nnz < m2.nnz
    # block count ~ S/qb * (window/qb + 1): sub-quadratic
    assert m2.nnz <= (4096 // 128) * (1024 // 128 + 2)


def test_mask_is_paper_format():
    """The mask is a genuine core CSR tensor — partitionable like any
    sparse tensor in the system."""
    from repro.core.partition import partition_by_bounds, partition_tensor_rows
    m = band_plan(2048, 128, 512)
    part = partition_tensor_rows(m, partition_by_bounds(m.shape[0], 4))
    assert part.vals_bounds[-1, 1] == m.nnz
