"""Elastic execution & fault recovery invariants (ISSUE 8, hypothesis
stub–compatible property tests).

The elastic contract, end to end:

  * ``relower`` onto a resized machine is BIT-FOR-BIT a fresh lower on
    that machine (integer-valued operands — reductions must agree
    exactly), while reusing ≥ 50% of shard-cache lookups on a
    migration-style P→P−1 under EVERY format family and both strategy
    spaces;
  * migration bounds (``elastic_row_bounds``) cover the domain and leave
    P−2 windows untouched;
  * ``SparseCheckpoint`` round-trips compressed trees + fingerprints:
    corrupted tensors are healed in place, unchanged ones are reported
    reused (their cache entries stay valid), and tuned-plan entries ride
    along;
  * a fault-injected ``run_with_recovery`` (device loss mid-loop)
    restores, shrinks to P−1, re-lowers with shard reuse, and produces
    bit-for-bit the unfaulted result — same for healed corruption and
    straggler-weight re-plans;
  * satellites: RestartPolicy backoff jitter, StepWatchdog warm-up,
    orphaned tmp-dir sweep, _flatten_with_names collisions.
"""
import os
import tempfile
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.core as rc
from repro.core import formats as F
from repro.core import plan_search as PS
from repro.core.lower import (clear_lowering_caches, default_nnz_schedule,
                              lower, rebuild_schedule, relower)
from repro.core.partition import elastic_row_bounds, partition_by_bounds
from repro.core.tensor import Tensor
from repro.distributed.mesh import resize_machine, shrink_machine
from repro.runtime.checkpoint import (CheckpointManager, SparseCheckpoint,
                                      _flatten_with_names)
from repro.runtime.elastic import run_with_recovery
from repro.runtime.fault import (DeviceLoss, FaultEvent, FaultInjector,
                                 RestartPolicy, StepWatchdog,
                                 StragglerMitigator)


def _int_sparse(rng, n, m, density=0.3):
    """Integer-valued sparse matrix: all partial sums are exact in fp32,
    so differently-ordered reductions must agree BIT FOR BIT."""
    return (rng.integers(-3, 4, (n, m)) *
            (rng.random((n, m)) < density)).astype(np.float32)


def _spmm_stmt(rng, n, m, J, fm=None):
    dB = _int_sparse(rng, n, m)
    dC = rng.integers(-3, 4, (m, J)).astype(np.float32)
    B = Tensor.from_dense("B", dB, fm or F.CSR())
    C = Tensor.from_dense("C", dC)
    return rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                        A=Tensor.zeros_dense("A", (n, J)), B=B, C=C)


_FAMILIES = {
    "csr": lambda: F.CSR(),
    "dcsr": lambda: F.DCSR(),
    "csc": lambda: F.CSC(),
    "coo": lambda: F.COO(2),
    "bcsr": lambda: F.BCSR((8, 8)),
    "bcsc": lambda: F.BCSC((8, 8)),
}


# ---------------------------------------------------------------------------
# Migration bounds
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(n=st.integers(4, 200), P=st.integers(2, 8), seed=st.integers(0, 99))
def test_elastic_bounds_cover_and_preserve(n, P, seed):
    rng = np.random.default_rng(seed)
    b = partition_by_bounds(n, P)
    dead = int(rng.integers(0, P))
    keep = elastic_row_bounds(b, dead)
    assert keep.shape == (P - 1, 2)
    # contiguous cover of [0, n)
    assert keep[0, 0] == 0 and keep[-1, 1] == n
    assert np.array_equal(keep[1:, 0], keep[:-1, 1])
    # P-2 of the surviving windows are bitwise rows of the original split
    orig = {(int(lo), int(hi)) for lo, hi in b}
    unchanged = sum((int(lo), int(hi)) in orig for lo, hi in keep)
    assert unchanged >= P - 2


# ---------------------------------------------------------------------------
# Resize equivalence + shard reuse (the tentpole assertions)
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(fam=st.sampled_from(sorted(_FAMILIES)), dead=st.integers(0, 3),
       nnz_space=st.booleans(), seed=st.integers(0, 999))
def test_relower_bitforbit_and_reuse(fam, dead, nnz_space, seed):
    rng = np.random.default_rng(seed)
    stmt = _spmm_stmt(rng, 48, 40, 8, fm=_FAMILIES[fam]())
    M4, M3 = rc.Machine(("x", 4)), rc.Machine(("x", 3))
    sched4 = default_nnz_schedule(stmt, M4) if nnz_space else None
    sched3 = default_nnz_schedule(stmt, M3) if nnz_space else None
    clear_lowering_caches()
    k4 = lower(stmt, M4, schedule=sched4, elastic=True)
    ref4 = np.asarray(k4.run())
    k3 = relower(k4, M3, dead=dead)
    out3 = np.asarray(k3.run())
    # bit-for-bit vs a fresh (equal-split) lower on the shrunk machine AND
    # vs the original P=4 result
    fresh = lower(stmt, M3, schedule=sched3)
    assert np.array_equal(out3, np.asarray(fresh.run()))
    assert np.array_equal(out3, ref4)
    # ≥ 50% of shard-cache lookups hit: P−2 surviving windows + the
    # replicated operand are reused, only the merged window re-packs
    assert k3.cache.shard_reuse >= 0.5
    assert k3.cache.shard_hits + k3.cache.shard_misses > 0
    assert k3.strategy.pieces == 3 and k3.machine is M3


def test_relower_regrid_and_weights():
    """Mesh-as-data beyond shrinking: re-factorize 1-D → 2-D, and re-plan
    in place with straggler weights through the same entry point."""
    rng = np.random.default_rng(7)
    stmt = _spmm_stmt(rng, 48, 40, 8)
    M4 = rc.Machine(("x", 4))
    M22 = rc.Machine(("x", 2), ("y", 2))
    clear_lowering_caches()
    k4 = lower(stmt, M4, elastic=True)
    ref = np.asarray(k4.run())
    k22 = relower(k4, M22)
    assert k22.strategy.is_grid
    assert tuple(d.size for d in k22.strategy.machine_dims) == (2, 2)
    assert np.array_equal(np.asarray(k22.run()), ref)
    # weighted re-plan on the SAME machine (nnz space)
    kn = lower(stmt, M4, schedule=default_nnz_schedule(stmt, M4),
               elastic=True)
    w = np.array([0.5, 1.0, 1.5, 1.0])
    kw = relower(kn, M4, weights=w)
    assert np.array_equal(np.asarray(kw.run()), ref)


def test_rebuild_schedule_matches_strategy_family():
    rng = np.random.default_rng(11)
    stmt = _spmm_stmt(rng, 48, 40, 8)
    M4 = rc.Machine(("x", 4))
    k = lower(stmt, M4, schedule=default_nnz_schedule(stmt, M4))
    s = rebuild_schedule(stmt, rc.Machine(("x", 3)), k.strategy)
    assert s.strategy().space == "nnz" and s.strategy().pieces == 3
    k2 = lower(stmt, M4)   # universe default
    s2 = rebuild_schedule(stmt, rc.Machine(("x", 2), ("y", 2)), k2.strategy)
    assert s2.strategy().is_grid and s2.strategy().pieces == 4


# ---------------------------------------------------------------------------
# Sparse checkpoint round-trip
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(fam=st.sampled_from(sorted(_FAMILIES)), seed=st.integers(0, 999))
def test_sparse_checkpoint_roundtrip(fam, seed):
    rng = np.random.default_rng(seed)
    stmt = _spmm_stmt(rng, 40, 32, 4, fm=_FAMILIES[fam]())
    tensors = {a.tensor.name: a.tensor for a in stmt.accesses()}
    B = tensors["B"]
    fp0 = B.fingerprint()
    ck = SparseCheckpoint(tempfile.mkdtemp(prefix="ck_"), keep=2)
    acc = np.arange(6, dtype=np.float32)
    ck.save(1, tensors, {"state": acc}, blocking=True)
    assert ck.stale_operands(tensors) == []
    # corrupt B in place -> detected by CRC, healed by restore
    B.vals.reshape(-1)[0] += 3.0
    assert ck.stale_operands(tensors) == ["B"]
    step, extra, info = ck.restore(tensors, {"state": acc})
    assert step == 1
    assert np.array_equal(extra["state"], acc)
    assert info["restored"] == ["B"]
    assert "C" in info["reused"]          # untouched operand not re-written
    assert B.fingerprint() == fp0         # tree healed bit-for-bit
    assert ck.stale_operands(tensors) == []


def test_sparse_checkpoint_carries_tuned_plans(tmp_path):
    rng = np.random.default_rng(3)
    stmt = _spmm_stmt(rng, 40, 32, 4)
    tensors = {a.tensor.name: a.tensor for a in stmt.accesses()}
    clear_lowering_caches()
    k = lower(stmt, rc.Machine(("x", 2)), schedule="auto")
    assert len(PS.export_tuned_entries()) >= 1
    key = PS.export_tuned_entries()[-1][0]
    ck = SparseCheckpoint(str(tmp_path), keep=2)
    ck.save(1, tensors, blocking=True)
    PS.clear_tuned_plan_cache()
    assert PS.export_tuned_entries() == []
    _, _, info = ck.restore(tensors)
    assert info["tuned_imported"] >= 1
    assert any(k2 == key for k2, _ in PS.export_tuned_entries())


# ---------------------------------------------------------------------------
# Injected-fault recovery through run_with_recovery
# ---------------------------------------------------------------------------

@settings(max_examples=4, deadline=None)
@given(fault_step=st.integers(1, 4), piece=st.integers(0, 3),
       seed=st.integers(0, 999))
def test_device_loss_recovers_bitforbit(fault_step, piece, seed):
    rng = np.random.default_rng(seed)
    dB = _int_sparse(rng, 48, 40)
    dC = rng.integers(-3, 4, (40, 8)).astype(np.float32)

    def mkstmt():
        B = Tensor.from_dense("B", dB.copy(), F.CSR())
        C = Tensor.from_dense("C", dC.copy())
        return rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                            A=Tensor.zeros_dense("A", (48, 8)), B=B, C=C)

    M4 = rc.Machine(("x", 4))
    clear_lowering_caches()
    ref, ref_rep = run_with_recovery(
        mkstmt(), M4, 6, ckpt_dir=tempfile.mkdtemp(prefix="ref_"))
    assert ref_rep.restarts == 0 and ref_rep.final_pieces == 4

    clear_lowering_caches()
    inj = FaultInjector(
        [FaultEvent(step=fault_step, kind="device_loss", piece=piece)])
    state, rep = run_with_recovery(
        mkstmt(), M4, 6, ckpt_dir=tempfile.mkdtemp(prefix="flt_"),
        injector=inj)
    # kill one device mid-loop -> checkpoint restore + P−1 re-plan ->
    # bit-for-bit the unfaulted result, with ≥ 50% shard reuse
    assert np.array_equal(state, ref)
    assert rep.restarts == 1
    assert rep.initial_pieces == 4 and rep.final_pieces == 3
    assert rep.shard_reuse >= 0.5
    assert rep.faults == [f"device_loss:{piece}@{fault_step}"]
    assert rep.restored_step is not None and rep.restored_step <= fault_step


def test_corruption_heals_and_matches(tmp_path_factory):
    rng = np.random.default_rng(5)
    dB = _int_sparse(rng, 48, 40)
    dC = rng.integers(-3, 4, (40, 8)).astype(np.float32)

    def mkstmt():
        B = Tensor.from_dense("B", dB.copy(), F.CSR())
        C = Tensor.from_dense("C", dC.copy())
        return rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                            A=Tensor.zeros_dense("A", (48, 8)), B=B, C=C)

    M4 = rc.Machine(("x", 4))
    clear_lowering_caches()
    ref, _ = run_with_recovery(mkstmt(), M4, 6,
                               ckpt_dir=str(tmp_path_factory.mktemp("r")))
    clear_lowering_caches()
    inj = FaultInjector([FaultEvent(step=2, kind="corrupt", tensor="B")])
    state, rep = run_with_recovery(
        mkstmt(), M4, 6, ckpt_dir=str(tmp_path_factory.mktemp("c")),
        injector=inj)
    assert np.array_equal(state, ref)
    assert rep.healed == ["B"] and rep.restarts == 0
    assert rep.final_pieces == 4          # corruption does not shrink


def test_straggler_triggers_weighted_replan(tmp_path_factory):
    rng = np.random.default_rng(6)
    dB = _int_sparse(rng, 48, 40)
    dC = rng.integers(-3, 4, (40, 8)).astype(np.float32)

    def mkstmt():
        B = Tensor.from_dense("B", dB.copy(), F.CSR())
        C = Tensor.from_dense("C", dC.copy())
        return rc.parse_tin("A(i,j) = B(i,k) * C(k,j)",
                            A=Tensor.zeros_dense("A", (48, 8)), B=B, C=C)

    M4 = rc.Machine(("x", 4))
    s0 = mkstmt()
    clear_lowering_caches()
    ref, _ = run_with_recovery(s0, M4, 8,
                               ckpt_dir=str(tmp_path_factory.mktemp("r")),
                               schedule=default_nnz_schedule(s0, M4))
    clear_lowering_caches()
    s1 = mkstmt()
    inj = FaultInjector([FaultEvent(step=s, kind="straggler", piece=2,
                                    slowdown_s=0.05) for s in (3, 4, 5)])
    mit = StragglerMitigator(4, report_budget=2)
    state, rep = run_with_recovery(
        s1, M4, 8, ckpt_dir=str(tmp_path_factory.mktemp("s")),
        schedule=default_nnz_schedule(s1, M4), injector=inj, mitigator=mit)
    assert np.array_equal(state, ref)     # weights change splits, not math
    assert rep.replans >= 1               # the lower(weights=) re-plan fired


# ---------------------------------------------------------------------------
# Satellites: jitter, warm-up, tmp sweep, name collisions, machine resize
# ---------------------------------------------------------------------------

def test_restart_backoff_jitter_spreads_delays():
    p = RestartPolicy(max_restarts=6, backoff_s=1.0, backoff_factor=2.0,
                      jitter=0.5, seed=42)
    sleeps, calls = [], {"n": 0}

    def boom():
        calls["n"] += 1
        if calls["n"] <= 4:
            raise RuntimeError("boom")

    p.run_with_restarts(boom, sleep=lambda s: sleeps.append(s))
    assert len(sleeps) == 4
    # jitter keeps each delay within ±50% of its nominal backoff value
    for s, nominal in zip(sleeps, (1.0, 2.0, 4.0, 8.0)):
        assert 0.5 * nominal <= s <= 1.5 * nominal
    assert len(set(sleeps)) == len(sleeps)   # not the lockstep herd
    # reproducible: same seed -> same schedule
    p2 = RestartPolicy(max_restarts=6, backoff_s=1.0, backoff_factor=2.0,
                       jitter=0.5, seed=42)
    sleeps2, calls["n"] = [], 0
    p2.run_with_restarts(boom, sleep=lambda s: sleeps2.append(s))
    assert sleeps == sleeps2
    # a zero base delay stays exactly zero under jitter (pinned tests rely
    # on this)
    p3 = RestartPolicy(backoff_s=0.0, jitter=0.9, seed=1)
    z, calls["n"] = [], 0
    p3.run_with_restarts(boom, sleep=lambda s: z.append(s))
    assert z == [0.0] * 4


def test_watchdog_warmup_suppresses_early_flags():
    wd = StepWatchdog(threshold=1.01, warmup=3)
    # the first `warmup` stops can never flag, even when wildly slow
    for dt in (0.001, 0.5, 0.9):
        wd.start()
        wd._t0 -= dt                     # simulate elapsed time
        assert wd.stop() is False
    wd.start()
    wd._t0 -= 50.0
    assert wd.stop() is True             # past warm-up, 50s ≫ median


def test_restore_sweeps_orphan_tmp_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, process_index=0)
    mgr.save(1, {"x": np.arange(4)}, blocking=True)
    orphan = tmp_path / "step_00000007.tmp"
    orphan.mkdir()
    (orphan / "leaf_00000_p0.npy").write_bytes(b"garbage")
    step, got = mgr.restore({"x": np.zeros(4, dtype=np.int64)})
    assert step == 1 and np.array_equal(got["x"], np.arange(4))
    assert not orphan.exists()           # crash debris swept
    assert (tmp_path / "step_00000001").exists()


def test_flatten_with_names_uniquifies_collisions():
    tree = {"a": {"b": 1}, "a/b": 2, "c": [3, 4]}
    names = [n for n, _ in _flatten_with_names(tree)]
    assert len(names) == len(set(names))
    assert sum(n.startswith("a/b") for n in names) == 2


def test_machine_resize_helpers():
    M = rc.Machine(("x", 4), ("y", 2))
    assert [d.size for d in shrink_machine(M).dims] == [3, 2]
    assert [d.size for d in shrink_machine(M, "y").dims] == [4, 1]
    assert [d.size for d in resize_machine(M, "y", 5).dims] == [4, 5]
    with pytest.raises(ValueError):
        shrink_machine(rc.Machine(("x", 1)))
    with pytest.raises(ValueError):
        resize_machine(M, "z", 2)
