"""Runtime: checkpoint atomicity/restore, pipeline determinism + resume,
fault policies, elastic resize."""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, Pipeline, TokenSource
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.elastic import plan_resize, valid_resize
from repro.runtime.fault import (RestartPolicy, StepWatchdog,
                                 StragglerMitigator)


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), process_index=0)
    state = {"params": {"w": jnp.arange(8.0)}, "step": 7,
             "cursor": {"step": 7, "shard": 0, "n_shards": 1, "seed": 0}}
    mgr.save(7, state, blocking=True)
    step, restored = mgr.restore(state)
    assert step == 7
    assert np.allclose(np.asarray(restored["params"]["w"]), np.arange(8.0))
    assert int(restored["step"]) == 7


def test_checkpoint_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, process_index=0)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.full(4, float(s))})
    mgr.wait()
    assert mgr.latest_step() == 4
    committed = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(committed) == 2          # gc kept last 2


def test_checkpoint_atomic_no_partial(tmp_path):
    """A .tmp dir never counts as a checkpoint."""
    mgr = CheckpointManager(str(tmp_path), process_index=0)
    (tmp_path / "step_00000009.tmp").mkdir()
    assert mgr.latest_step() is None


def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=3)
    p1 = Pipeline(cfg)
    batches = [next(p1) for _ in range(5)]
    cursor = p1.cursor()
    later = [next(p1) for _ in range(3)]
    p1.close()

    p2 = Pipeline(cfg)
    p2.restore(cursor)
    replay = [next(p2) for _ in range(3)]
    p2.close()
    for a, b in zip(later, replay):
        assert np.array_equal(a["tokens"], b["tokens"])
    # pure-function property: batch_at is reproducible
    src = TokenSource(cfg)
    assert np.array_equal(src.batch_at(2)["tokens"], batches[2]["tokens"])


def test_pipeline_shards_disjoint_rngs():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=1)
    src = TokenSource(cfg)
    b0 = src.batch_at(0, shard=0, n_shards=2)["tokens"]
    b1 = src.batch_at(0, shard=1, n_shards=2)["tokens"]
    assert b0.shape == (4, 32)
    assert not np.array_equal(b0, b1)


def test_restart_policy_retries_then_succeeds():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("node lost")

    restarts = RestartPolicy(max_restarts=5, backoff_s=0.0).run_with_restarts(
        flaky, sleep=lambda s: None)
    assert restarts == 2 and calls["n"] == 3


def test_restart_policy_budget_exhausted():
    def always_fails():
        raise RuntimeError("dead")

    with pytest.raises(RuntimeError):
        RestartPolicy(max_restarts=2, backoff_s=0.0).run_with_restarts(
            always_fails, sleep=lambda s: None)


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(threshold=2.0)
    t = [0.0]

    # monkeypatch time by injecting durations directly
    for dt in [0.1] * 10:
        wd.times.append(dt)
    wd._t0 = time.monotonic() - 1.0   # 1s step vs 0.1s median
    assert wd.stop() is True
    wd._t0 = time.monotonic() - 0.1
    assert wd.stop() is False


def test_straggler_mitigator_rebalances():
    mit = StragglerMitigator(4, report_budget=2)
    assert mit.report_slow(1) is False
    assert mit.report_slow(1) is True       # budget hit -> re-plan
    b = mit.weighted_nonzero_bounds(1000)
    counts = b[:, 1] - b[:, 0]
    assert counts.sum() == 1000
    assert counts[1] < counts[0]            # slow shard got less work
    # bounds remain a valid partition
    assert b[0, 0] == 0 and np.all(b[1:, 0] == b[:-1, 1])


def test_elastic_resize_plan():
    assert plan_resize((16, 16), 256, 16) == (16, 16)
    assert plan_resize((16, 16), 192, 16) == (8, 16)   # lost nodes
    assert plan_resize((16, 16), 8, 16) is None        # can't fit TP
    assert valid_resize(256, 8) and not valid_resize(256, 6)
