"""End-to-end lowering: all six paper kernels × both distribution
strategies against dense oracles (paper §VI-A expressions)."""
import numpy as np
import pytest

import repro.core as rc
from repro.core import formats as F
from repro.core.lower import default_nnz_schedule, default_row_schedule, lower
from repro.core.tensor import Tensor

M4 = rc.Machine(("x", 4))
M3 = rc.Machine(("x", 3))   # non-divisible piece count


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(42)
    n, m = 50, 37
    dB = ((rng.random((n, m)) < 0.2) *
          rng.standard_normal((n, m))).astype(np.float32)
    dB[3] = rng.standard_normal(m).astype(np.float32)  # skewed row
    return rng, n, m, dB


def _spmv_stmt(dB, n, m):
    B = Tensor.from_dense("B", dB, F.CSR())
    c = Tensor.from_dense("c", np.arange(m, dtype=np.float32) / m)
    a = Tensor.zeros_dense("a", (n,))
    return rc.parse_tin("a(i) = B(i,j) * c(j)", a=a, B=B, c=c), B, c


@pytest.mark.parametrize("machine", [M4, M3], ids=["p4", "p3"])
@pytest.mark.parametrize("strategy", ["rows", "nnz"])
def test_spmv(data, machine, strategy):
    rng, n, m, dB = data
    stmt, B, c = _spmv_stmt(dB, n, m)
    sched = (default_row_schedule(stmt, machine) if strategy == "rows"
             else default_nnz_schedule(stmt, machine))
    k = lower(stmt, machine, schedule=sched)
    expected = dB @ np.asarray(c.to_dense())
    assert np.allclose(k.run(), expected, atol=1e-4)
    if strategy == "nnz":
        assert k.imbalance() < 0.1          # paper C3: balanced
    assert k.comm.total_network_bytes() > 0  # c replication costed


@pytest.mark.parametrize("strategy", ["rows", "nnz"])
def test_spmm(data, strategy):
    rng, n, m, dB = data
    B = Tensor.from_dense("B", dB, F.CSR())
    dC = rng.standard_normal((m, 13)).astype(np.float32)
    C = Tensor.from_dense("C", dC)
    A = Tensor.zeros_dense("A", (n, 13))
    stmt = rc.parse_tin("A(i,j) = B(i,k) * C(k,j)", A=A, B=B, C=C)
    sched = (default_row_schedule(stmt, M4) if strategy == "rows"
             else default_nnz_schedule(stmt, M4))
    assert np.allclose(lower(stmt, M4, schedule=sched).run(), dB @ dC,
                       atol=1e-3)


def test_spadd3_fused(data):
    rng, n, m, dB = data
    d2 = ((rng.random((n, m)) < 0.15) *
          rng.standard_normal((n, m))).astype(np.float32)
    d3 = ((rng.random((n, m)) < 0.1) *
          rng.standard_normal((n, m))).astype(np.float32)
    Bt = Tensor.from_dense("B", dB, F.CSR())
    Ct = Tensor.from_dense("C", d2, F.CSR())
    Dt = Tensor.from_dense("D", d3, F.CSR())
    A = Tensor.from_dense("A", np.zeros((n, m), np.float32), F.CSR())
    stmt = rc.parse_tin("A(i,j) = B(i,j) + C(i,j) + D(i,j)",
                        A=A, B=Bt, C=Ct, D=Dt)
    res = lower(stmt, M4).run()
    assert np.allclose(res.to_dense(), dB + d2 + d3, atol=1e-4)
    # union pattern, not sum of nnz
    assert res.nnz == int(((dB + d2 + d3) != 0).sum())


def test_sddmm_nnz(data):
    rng, n, m, dB = data
    K = 8
    B = Tensor.from_dense("B", dB, F.CSR())
    dC = rng.standard_normal((n, K)).astype(np.float32)
    dD = rng.standard_normal((K, m)).astype(np.float32)
    A = Tensor.from_dense("A", (dB != 0) * 1.0, F.CSR())
    stmt = rc.parse_tin("A(i,j) = B(i,j) * C(i,k) * D(k,j)", A=A, B=B,
                        C=Tensor.from_dense("C", dC),
                        D=Tensor.from_dense("D", dD))
    k = lower(stmt, M4, schedule=default_nnz_schedule(stmt, M4))
    exp = (dB != 0) * dB * (dC @ dD)
    assert np.allclose(k.run().to_dense(), exp, atol=1e-3)
    assert k.imbalance() < 0.1


@pytest.mark.parametrize("strategy", ["rows", "nnz"])
def test_spttv(data, strategy):
    rng = np.random.default_rng(7)
    dims = (20, 15, 11)
    dB3 = ((rng.random(dims) < 0.1) *
           rng.standard_normal(dims)).astype(np.float32)
    cv = rng.standard_normal(dims[2]).astype(np.float32)
    B = Tensor.from_dense("B", dB3, F.CSF(3))
    c = Tensor.from_dense("c", cv)
    A = Tensor.from_dense("A", np.einsum("ijk,k->ij", dB3, cv) * 0, F.CSR())
    stmt = rc.parse_tin("A(i,j) = B(i,j,k) * c(k)", A=A, B=B, c=c)
    sched = (default_row_schedule(stmt, M4) if strategy == "rows"
             else default_nnz_schedule(stmt, M4))
    exp = np.einsum("ijk,k->ij", dB3, cv)
    assert np.allclose(lower(stmt, M4, schedule=sched).run().to_dense(),
                       exp, atol=1e-4)


@pytest.mark.parametrize("strategy", ["rows", "nnz"])
def test_spmttkrp(data, strategy):
    rng = np.random.default_rng(8)
    dims, L = (20, 15, 11), 7
    dB3 = ((rng.random(dims) < 0.1) *
           rng.standard_normal(dims)).astype(np.float32)
    dC = rng.standard_normal((dims[1], L)).astype(np.float32)
    dD = rng.standard_normal((dims[2], L)).astype(np.float32)
    B = Tensor.from_dense("B", dB3, F.CSF(3))
    stmt = rc.parse_tin(
        "A(i,l) = B(i,j,k) * C(j,l) * D(k,l)",
        A=Tensor.zeros_dense("A", (dims[0], L)), B=B,
        C=Tensor.from_dense("C", dC), D=Tensor.from_dense("D", dD))
    sched = (default_row_schedule(stmt, M4) if strategy == "rows"
             else default_nnz_schedule(stmt, M4))
    exp = np.einsum("ijk,jl,kl->il", dB3, dC, dD)
    assert np.allclose(lower(stmt, M4, schedule=sched).run(), exp,
                       atol=1e-3)


def test_interpreter_matches_oracle(data):
    """The CTF-analog baseline is semantically correct (just slow)."""
    rng, n, m, dB = data
    stmt, B, c = _spmv_stmt(dB, n, m)
    from repro.core.interp import interpret
    assert np.allclose(interpret(stmt), dB @ np.asarray(c.to_dense()),
                       atol=1e-4)


def test_mismatched_distribution_costed(data):
    """Paper §II-D (C4): data distribution ≠ computation distribution is
    legal but charges redistribution bytes."""
    rng, n, m, dB = data
    stmt, B, c = _spmv_stmt(dB, n, m)
    from repro.core.tdn import dist
    dists = {"B": dist(B, "xy ~f> f", M4)}   # nnz data distribution
    k = lower(stmt, M4, distributions=dists)  # row-based computation
    assert k.comm.redistribute_bytes > 0
    k2 = lower(stmt, M4, distributions={"B": dist(B, "xy -> x", M4)})
    assert k2.comm.redistribute_bytes == 0
