"""Optimizer + gradient-compression properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.grad_compress import (compress_int8_ef, compress_topk_ef,
                                       int8_dequantize, int8_quantize,
                                       topk_densify, topk_sparsify)
from repro.optim.schedules import cosine_with_warmup


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    opt = adamw_init(params)
    target = jnp.array([1.0, 1.0, 1.0])

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(params, g, opt, lr=0.05,
                                      weight_decay=0.0)
    assert float(loss(params)) < 1e-2


def test_grad_clip_norm():
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    g = {"w": jnp.full(4, 100.0)}
    _, _, gnorm = adamw_update(params, g, opt, lr=0.0, grad_clip_norm=1.0)
    assert float(gnorm) == pytest.approx(200.0, rel=1e-4)


def test_schedule_warmup_then_decay():
    lr0 = float(cosine_with_warmup(0, peak_lr=1.0, warmup_steps=10,
                                   total_steps=100))
    lr_peak = float(cosine_with_warmup(10, peak_lr=1.0, warmup_steps=10,
                                       total_steps=100))
    lr_end = float(cosine_with_warmup(100, peak_lr=1.0, warmup_steps=10,
                                      total_steps=100))
    assert lr0 == 0.0 and lr_peak == pytest.approx(1.0) and \
        lr_end == pytest.approx(0.1, rel=1e-3)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_int8_quantize_bounded_error(seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(64).astype(np.float32))
    q, s = int8_quantize(g)
    err = jnp.abs(int8_dequantize(q, s) - g).max()
    assert float(err) <= float(s) * 0.5 + 1e-6


def test_error_feedback_accumulates():
    """EF property: over repeated identical grads, the quantized stream's
    mean converges to the true gradient (no bias)."""
    g = {"w": jnp.asarray(np.linspace(-0.01, 0.01, 32), jnp.float32)}
    err = None
    acc = jnp.zeros(32)
    for _ in range(64):
        q, s, err = compress_int8_ef(g, err)
        acc = acc + int8_dequantize(q["w"], s["w"])
    mean = acc / 64
    assert float(jnp.abs(mean - g["w"]).max()) < 2e-3


def test_topk_roundtrip_and_ef():
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .standard_normal(128).astype(np.float32))}
    sparse, err, dense = compress_topk_ef(g, None, k_frac=0.1)
    v, i = sparse["w"]
    assert v.shape[0] == 12  # 10% of 128
    # densified top-k + error == original
    total = dense["w"] + err["w"]
    assert np.allclose(np.asarray(total), np.asarray(g["w"]), atol=1e-6)
