"""Format language + tensor assembly: round-trip properties (hypothesis)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import formats as F
from repro.core.tensor import Tensor

FORMATS_2D = [F.CSR(), F.CSC(), F.DCSR(), F.COO(2), F.DenseMat()]
FORMATS_3D = [F.CSF(3), F.DDC(), F.COO(3)]


@st.composite
def sparse_2d(draw):
    n = draw(st.integers(1, 24))
    m = draw(st.integers(1, 24))
    density = draw(st.floats(0.0, 0.6))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    dense = ((rng.random((n, m)) < density) *
             rng.standard_normal((n, m))).astype(np.float32)
    return dense


@settings(max_examples=40, deadline=None)
@given(dense=sparse_2d(), fmt_idx=st.integers(0, len(FORMATS_2D) - 1))
def test_roundtrip_2d(dense, fmt_idx):
    fmt = FORMATS_2D[fmt_idx]
    t = Tensor.from_dense("T", dense, fmt)
    assert np.allclose(t.to_dense(), dense)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), fmt_idx=st.integers(0, len(FORMATS_3D) - 1),
       density=st.floats(0.0, 0.4))
def test_roundtrip_3d(seed, fmt_idx, density):
    rng = np.random.default_rng(seed)
    shape = tuple(rng.integers(1, 10, 3))
    dense = ((rng.random(shape) < density) *
             rng.standard_normal(shape)).astype(np.float32)
    t = Tensor.from_dense("T", dense, FORMATS_3D[fmt_idx])
    assert np.allclose(t.to_dense(), dense)


@settings(max_examples=30, deadline=None)
@given(dense=sparse_2d())
def test_coords_sorted_and_unique(dense):
    """Invariant: CSR coords are row-major sorted, no duplicates."""
    t = Tensor.from_dense("T", dense, F.CSR())
    c = t.coords()
    key = c[:, 0].astype(np.int64) * dense.shape[1] + c[:, 1]
    assert np.all(np.diff(key) > 0) or key.size <= 1


def test_nnz_matches_dense(rng):
    dense = ((rng.random((13, 17)) < 0.3) *
             rng.standard_normal((13, 17))).astype(np.float32)
    for fmt in FORMATS_2D[:-1]:
        t = Tensor.from_dense("T", dense, fmt)
        assert t.nnz == int((dense != 0).sum())


def test_from_coo_dedupes(rng):
    coords = np.array([[0, 1], [0, 1], [2, 3]])
    vals = np.array([1.0, 2.0, 5.0], np.float32)
    t = Tensor.from_coo("T", (4, 4), coords, vals, F.CSR())
    d = t.to_dense()
    assert d[0, 1] == 3.0 and d[2, 3] == 5.0 and t.nnz == 2


def test_dense_after_compressed_rejected():
    with pytest.raises(NotImplementedError):
        Tensor.from_coo("T", (3, 3, 3), np.array([[0, 0, 0]]),
                        np.array([1.0], np.float32),
                        F.Format((F.Compressed, F.Dense, F.Compressed)))
