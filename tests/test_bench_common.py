"""benchmarks.common regression pins (ISSUE 6 satellites): the JSON
drain must not drop duplicate-name rows, and ``time_fn`` must return a
true median for even iteration counts."""
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import common  # noqa: E402


def test_drain_keeps_duplicate_names():
    """Cold/warm patterns time the same name twice; dict(RESULTS) used to
    silently keep only the last row. Duplicates uniquify as name#N."""
    common.RESULTS.clear()
    common.csv_row("cold", 10.0)
    common.csv_row("cold", 2.0)
    common.csv_row("warm", 1.0)
    out = common.drain_results()
    assert out == {"cold": 10.0, "cold#2": 2.0, "warm": 1.0}
    assert common.RESULTS == []      # drained


def test_time_fn_true_median_even_iters(monkeypatch):
    # 4 timed intervals of 1, 1, 8, 4 seconds -> sorted 1,1,4,8: the true
    # median is 2.5 (the old upper-middle pick returned 4).
    ticks = iter([0.0, 1.0, 1.0, 2.0, 2.0, 10.0, 10.0, 14.0])
    monkeypatch.setattr(common.time, "perf_counter", lambda: next(ticks))
    assert common.time_fn(lambda: None, warmup=0, iters=4) == \
        pytest.approx(2.5)


def test_time_fn_median_odd_iters(monkeypatch):
    # intervals 1, 3, 2 -> median 2
    ticks = iter([0.0, 1.0, 1.0, 4.0, 4.0, 6.0])
    monkeypatch.setattr(common.time, "perf_counter", lambda: next(ticks))
    assert common.time_fn(lambda: None, warmup=0, iters=3) == \
        pytest.approx(2.0)
