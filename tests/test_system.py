"""End-to-end behaviour tests for the whole system (deliverable c).

Covers: train-loop learning + checkpoint/restart determinism, the serve
loop, the TDN string front-end, and the scheduling-language API surface
from the paper's Figure 1.
"""
import numpy as np
import pytest

import repro.core as rc
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import formats as F
from repro.core.schedule import CPUThread, Schedule
from repro.core.tdn import Machine, dist
from repro.core.tensor import Tensor


def _tiny_cfg(**kw):
    base = dict(name="sys-dense", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
                head_dim=16, remat=False, dtype="float32")
    base.update(kw)
    return ArchConfig(**base)


def test_training_learns_and_checkpoints(tmp_path):
    from repro.launch.train import Trainer
    cfg = _tiny_cfg()
    shape = ShapeConfig("t", "train", seq_len=64, global_batch=8)
    tr = Trainer(cfg, shape, ckpt_dir=str(tmp_path), ckpt_every=20,
                 total_steps=60, peak_lr=5e-3)
    tr.run(60)
    losses = [m["loss"] for m in tr.metrics_log]
    # learns the structured corpus: best tail loss clearly below the head
    assert min(losses[30:]) < losses[0] - 0.03, (losses[0], min(losses[30:]))
    assert tr.ckpt.latest_step() is not None

    # restart from checkpoint reproduces the same forward batch sequence
    tr2 = Trainer(cfg, shape, ckpt_dir=str(tmp_path), ckpt_every=20,
                  total_steps=60, peak_lr=5e-3)
    assert tr2.step == 60                   # resumed
    b1 = next(tr.pipeline)
    b2 = next(tr2.pipeline)
    assert np.array_equal(b1["tokens"], b2["tokens"])


def test_serve_loop_generates():
    from repro.launch.serve import Request, Server
    cfg = _tiny_cfg()
    srv = Server(cfg, slots=2, context=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(2, 500, 5, dtype=np.int32),
                    max_new=8) for i in range(4)]
    out = srv.run(reqs)
    assert set(out) == {0, 1, 2, 3}
    assert all(len(v) == 8 for v in out.values())


def test_paper_figure1_api_surface():
    """The full Fig. 1 program spells out in this framework."""
    pieces = 4
    M = Machine(("x", pieces))
    rng = np.random.default_rng(0)
    n, m = 40, 30
    dense = ((rng.random((n, m)) < 0.2) *
             rng.standard_normal((n, m))).astype(np.float32)
    a = Tensor.zeros_dense("a", (n,))
    B = Tensor.from_dense("B", dense, F.CSR())
    c = Tensor.from_dense("c", rng.standard_normal(m).astype(np.float32))

    dists = {"a": dist(a, "x -> x", M), "B": dist(B, "xy -> x", M),
             "c": dist(c, "x -> *", M)}
    i, j, io, ii = rc.index_vars("i j io ii")
    stmt = rc.Assignment(a(i), B(i, j) * c(j))
    s = (Schedule(stmt, M)
         .divide(i, io, ii, M.x)
         .distribute(io)
         .communicate([a, B, c], io)
         .parallelize(ii, CPUThread))
    k = rc.lower_stmt(stmt, M, schedule=s, distributions=dists)
    assert np.allclose(k.run(), dense @ np.asarray(c.to_dense()), atol=1e-4)
    assert k.leaf_name == "spmv_rows"
    # matched data distribution: no redistribution charged
    assert k.comm.redistribute_bytes == 0


def test_tdn_string_forms():
    M = Machine(("x", 4))
    rng = np.random.default_rng(1)
    dense = ((rng.random((20, 20)) < 0.3) *
             np.ones((20, 20))).astype(np.float32)
    B = Tensor.from_dense("B", dense, F.CSR())
    d_row = dist(B, "xy -> x", M)
    d_nnz = dist(B, "xy ~f> f", M)
    d_rep = dist(B, "xy -> *", M)
    assert not d_row.nonzero and not d_row.replicate
    assert d_nnz.nonzero and d_nnz.fused == ("x", "y")
    assert d_rep.replicate
    # plans materialize coherently
    sh = d_nnz.materialize(B)
    assert sh.kind == "coo_nnz"
    counts = sh.arrays["nnz_count"]
    # ceil-div chunks: shards differ by at most pieces-1 elements
    assert counts.max() - counts.min() < 4
